"""Tests: WAL journaling, recovery replay, abandoned-lock release, GC."""
import jax.numpy as jnp
import numpy as np

from repro.core import cas, gc, header as hdr, mvcc, si, wal
from repro.core.tsoracle import VectorOracle


def _run_workload(n_rounds=4, n_threads=3, n_records=8, width=2,
                  journal=None):
    tbl = mvcc.init_table(n_records, width, n_old=2, n_overflow=4)
    o = VectorOracle(n_threads=n_threads)
    st = o.init()

    def fn(rh, rd, rts):
        return rd[:, :1, :].at[..., 0].add(1)  # write-set = read-set[0] + 1

    import jax
    key = jax.random.PRNGKey(0)
    for r in range(n_rounds):
        key, sub = jax.random.split(key)
        slots = jax.random.randint(sub, (n_threads, 2), 0, n_records)
        batch = si.TxnBatch(
            tid=jnp.arange(n_threads, dtype=jnp.int32),
            read_slots=slots.astype(jnp.int32),
            read_mask=jnp.ones((n_threads, 2), bool),
            write_ref=jnp.zeros((n_threads, 1), jnp.int32),
            write_mask=jnp.ones((n_threads, 1), bool),
        )
        rts = o.read(st)
        out = si.run_round(tbl, o, st, batch, fn)
        if journal is not None:
            wslots = jnp.take_along_axis(batch.read_slots,
                                         batch.write_ref, axis=1)
            cts = rts[jnp.arange(n_threads)] + 1
            new_hdr = hdr.pack(
                jnp.arange(n_threads, dtype=jnp.uint32)[:, None],
                cts[:, None])
            new_data = out.read_data[:, :1, :].at[..., 0].add(1)
            journal = wal.append(
                journal, jnp.arange(n_threads, dtype=jnp.int32),
                out.oracle_state.vec, wslots, new_hdr, new_data,
                batch.write_mask, out.committed)
        tbl, st = out.table, out.oracle_state
        tbl = mvcc.version_mover(tbl)
    return tbl, st, journal


def test_wal_replay_reconstructs_state():
    j = wal.init_journal(n_threads=3, capacity=8, n_slots=3, ws=1, width=2,
                         n_replicas=2)
    tbl, st, j = _run_workload(journal=j)
    fresh = mvcc.init_table(8, 2, n_old=2, n_overflow=4)
    recovered = wal.replay(j, fresh)
    # every record's current version must match (payloads and version tags)
    np.testing.assert_array_equal(np.asarray(recovered.cur_data),
                                  np.asarray(tbl.cur_data))
    np.testing.assert_array_equal(
        np.asarray(hdr.commit_ts(recovered.cur_hdr)),
        np.asarray(hdr.commit_ts(tbl.cur_hdr)))


def test_wal_replay_uses_surviving_replica():
    j = wal.init_journal(n_threads=3, capacity=8, n_slots=3, ws=1, width=2,
                         n_replicas=2)
    tbl, st, j = _run_workload(journal=j)
    fresh = mvcc.init_table(8, 2, n_old=2, n_overflow=4)
    recovered = wal.replay(j, fresh,
                           survivors=jnp.array([False, True]))
    np.testing.assert_array_equal(np.asarray(recovered.cur_data),
                                  np.asarray(tbl.cur_data))


def test_release_abandoned_locks():
    """A compute server dies between CAS and install; the monitor unlocks."""
    tbl = mvcc.init_table(4, 2, n_old=2, n_overflow=2)
    j = wal.init_journal(n_threads=2, capacity=4, n_slots=2, ws=1, width=2)
    # thread 1 locks slot 2 then crashes (no install, no outcome logged)
    expected = tbl.cur_hdr[jnp.array([2])]
    res = cas.arbitrate(tbl.cur_hdr, jnp.array([2]), expected,
                        jnp.array([1], jnp.uint32), jnp.array([True]))
    assert bool(res.granted[0])
    tbl = tbl._replace(cur_hdr=res.new_hdr)
    j = wal.append(j, jnp.array([1], jnp.int32),
                   jnp.zeros((2,), jnp.uint32),
                   jnp.array([[2]], jnp.int32),
                   hdr.pack(jnp.uint32(1), jnp.uint32(1))[None, None],
                   jnp.zeros((1, 1, 2), jnp.int32),
                   jnp.array([[True]]),
                   jnp.array([False]))  # undetermined outcome
    assert bool(hdr.is_locked(tbl.cur_hdr[2]))
    tbl = wal.release_abandoned_locks(j, tbl, dead_tid=1)
    assert not bool(hdr.is_locked(tbl.cur_hdr[2]))


def test_gc_snapshot_log_and_safe_vector():
    log = gc.init_log(4, n_slots=2)
    log = gc.take_snapshot(log, 100, jnp.array([1, 1], jnp.uint32))
    log = gc.take_snapshot(log, 200, jnp.array([3, 2], jnp.uint32))
    safe = gc.safe_vector(log, now=260, max_txn_time=100)
    np.testing.assert_array_equal(np.asarray(safe), [1, 1])
    safe2 = gc.safe_vector(log, now=400, max_txn_time=100)
    np.testing.assert_array_equal(np.asarray(safe2), [3, 2])


def test_gc_collect_marks_only_superseded():
    tbl = mvcc.init_table(2, 2, n_old=1, n_overflow=4)
    s = jnp.array([0], jnp.int32)
    for v in range(1, 5):  # versions 1..4 by thread 1; 1..3 spill to overflow
        out = mvcc.install(tbl, s, hdr.pack(jnp.uint32(1), jnp.uint32(v))[None],
                           jnp.full((1, 2), v, jnp.int32), jnp.array([True]))
        tbl = mvcc.version_mover(out.table)
    safe = jnp.array([0, 3], jnp.uint32)  # oldest live snapshot sees v3
    tbl2 = gc.collect(tbl, safe)
    # versions 1,2 in overflow must be doomed; v3 must survive
    ovf_cts = np.asarray(hdr.commit_ts(tbl2.ovf_hdr[0]))
    deleted = np.asarray(hdr.is_deleted(tbl2.ovf_hdr[0]))
    for cts, dead in zip(ovf_cts, deleted):
        if cts in (1, 2):
            assert dead
        if cts == 3:
            assert not dead
    # reads at the safe snapshot still succeed
    vr = mvcc.read_visible(tbl2, s, safe)
    assert bool(vr.found[0]) and int(hdr.commit_ts(vr.hdr[0])) == 3


def test_gc_reclaimable_fraction_monotone():
    tbl = mvcc.init_table(2, 2, n_old=1, n_overflow=4)
    f0 = float(gc.reclaimable_fraction(tbl))
    s = jnp.array([0], jnp.int32)
    for v in range(1, 5):
        out = mvcc.install(tbl, s, hdr.pack(jnp.uint32(1), jnp.uint32(v))[None],
                           jnp.full((1, 2), v, jnp.int32), jnp.array([True]))
        tbl = mvcc.version_mover(out.table)
    tbl = gc.collect(tbl, jnp.array([0, 4], jnp.uint32))
    assert float(gc.reclaimable_fraction(tbl)) <= f0  # fresh init all deleted
