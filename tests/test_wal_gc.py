"""Tests: WAL journaling, recovery replay, abandoned-lock release, GC —
including the §5.3 sustained-execution pieces (snapshot-ring wraparound,
reclaimed-slot version moving, lazy truncation, and the per-shard mesh
sweep, which runs whenever the process sees ≥2 CPU devices, e.g. under CI's
8-forced-host-device step)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import cas, gc, header as hdr, mvcc, si, store, wal
from repro.core.tsoracle import VectorOracle


def _run_workload(n_rounds=4, n_threads=3, n_records=8, width=2,
                  journal=None, ckpt_round=None):
    tbl = mvcc.init_table(n_records, width, n_old=2, n_overflow=4)
    o = VectorOracle(n_threads=n_threads)
    st = o.init()
    ckpt = None

    def fn(rh, rd, rts):
        return rd[:, :1, :].at[..., 0].add(1)  # write-set = read-set[0] + 1

    import jax
    key = jax.random.PRNGKey(0)
    for r in range(n_rounds):
        key, sub = jax.random.split(key)
        slots = jax.random.randint(sub, (n_threads, 2), 0, n_records)
        batch = si.TxnBatch(
            tid=jnp.arange(n_threads, dtype=jnp.int32),
            read_slots=slots.astype(jnp.int32),
            read_mask=jnp.ones((n_threads, 2), bool),
            write_ref=jnp.zeros((n_threads, 1), jnp.int32),
            write_mask=jnp.ones((n_threads, 1), bool),
        )
        rts = o.read(st)
        out = si.run_round(tbl, o, st, batch, fn)
        if journal is not None:
            wslots = jnp.take_along_axis(batch.read_slots,
                                         batch.write_ref, axis=1)
            cts = rts[jnp.arange(n_threads)] + 1
            new_hdr = hdr.pack(
                jnp.arange(n_threads, dtype=jnp.uint32)[:, None],
                cts[:, None])
            new_data = out.read_data[:, :1, :].at[..., 0].add(1)
            journal = wal.append_intent(
                journal, jnp.arange(n_threads, dtype=jnp.int32), rts,
                wslots, new_hdr, new_data, batch.write_mask,
                round_no=r, seq=0)
            journal = wal.append_outcome(
                journal, jnp.arange(n_threads, dtype=jnp.int32),
                out.committed)
        tbl, st = out.table, out.oracle_state
        tbl = mvcc.version_mover(tbl)
        if r == ckpt_round:
            ckpt = (tbl, journal.used)
    if ckpt_round is not None:
        return tbl, st, journal, ckpt
    return tbl, st, journal


def test_wal_replay_reconstructs_state():
    j = wal.init_journal(n_threads=3, capacity=8, n_slots=3, ws=1, width=2,
                         n_replicas=2)
    tbl, st, j = _run_workload(journal=j)
    fresh = mvcc.init_table(8, 2, n_old=2, n_overflow=4)
    recovered = wal.replay(j, fresh)
    # every record's current version must match (payloads and version tags)
    np.testing.assert_array_equal(np.asarray(recovered.cur_data),
                                  np.asarray(tbl.cur_data))
    np.testing.assert_array_equal(
        np.asarray(hdr.commit_ts(recovered.cur_hdr)),
        np.asarray(hdr.commit_ts(tbl.cur_hdr)))


def test_wal_replay_uses_surviving_replica():
    j = wal.init_journal(n_threads=3, capacity=8, n_slots=3, ws=1, width=2,
                         n_replicas=2)
    tbl, st, j = _run_workload(journal=j)
    fresh = mvcc.init_table(8, 2, n_old=2, n_overflow=4)
    recovered = wal.replay(j, fresh,
                           survivors=jnp.array([False, True]))
    np.testing.assert_array_equal(np.asarray(recovered.cur_data),
                                  np.asarray(tbl.cur_data))


def _lock(tbl, slot, prio):
    expected = tbl.cur_hdr[jnp.array([slot])]
    res = cas.arbitrate(tbl.cur_hdr, jnp.array([slot]), expected,
                        jnp.array([prio], jnp.uint32), jnp.array([True]))
    assert bool(res.granted[0])
    return tbl._replace(cur_hdr=res.new_hdr)


def _intent(j, tid, slot, cts, resolved=None):
    """Append a one-write intent entry for ``tid``; resolve it iff asked."""
    j = wal.append_intent(
        j, jnp.array([tid], jnp.int32), jnp.zeros((2,), jnp.uint32),
        jnp.array([[slot]], jnp.int32),
        hdr.pack(jnp.uint32(tid), jnp.uint32(cts))[None, None],
        jnp.zeros((1, 1, 2), jnp.int32), jnp.array([[True]]))
    if resolved is not None:
        j = wal.append_outcome(j, jnp.array([tid], jnp.int32),
                               jnp.array([resolved]))
    return j


def test_release_abandoned_locks():
    """A compute server dies between CAS and install; the monitor unlocks."""
    tbl = mvcc.init_table(4, 2, n_old=2, n_overflow=2)
    j = wal.init_journal(n_threads=2, capacity=4, n_slots=2, ws=1, width=2)
    # thread 1 locks slot 2 then crashes (no install, no outcome logged)
    tbl = _lock(tbl, 2, prio=1)
    j = _intent(j, tid=1, slot=2, cts=1)   # undetermined: no outcome record
    assert bool(hdr.is_locked(tbl.cur_hdr[2]))
    tbl = wal.release_abandoned_locks(j, tbl, dead_tid=1)
    assert not bool(hdr.is_locked(tbl.cur_hdr[2]))


def test_release_abandoned_locks_scans_all_unresolved():
    """Bugfix regression: the monitor must scan EVERY unresolved entry in
    the dead thread's live window. The old code looked only at the *last*
    entry, so a lock taken by an earlier in-flight sub-round entry leaked
    forever (and with ``used == 0`` it read the stale slot capacity-1)."""
    tbl = mvcc.init_table(6, 2, n_old=2, n_overflow=2)
    j = wal.init_journal(n_threads=2, capacity=4, n_slots=2, ws=1, width=2)
    # a RESOLVED committed entry naming slot 1 — its lock (held by someone
    # else now) must NOT be released on the dead thread's behalf
    j = _intent(j, tid=1, slot=1, cts=1, resolved=True)
    tbl = _lock(tbl, 1, prio=0)
    # two in-flight sub-round entries, both undetermined, then the crash
    tbl = _lock(tbl, 2, prio=1)
    j = _intent(j, tid=1, slot=2, cts=2)
    tbl = _lock(tbl, 3, prio=1)
    j = _intent(j, tid=1, slot=3, cts=2)
    tbl = wal.release_abandoned_locks(j, tbl, dead_tid=1)
    assert not bool(hdr.is_locked(tbl.cur_hdr[3]))
    assert not bool(hdr.is_locked(tbl.cur_hdr[2])), \
        "earlier unresolved entry's lock leaked (last-entry-only scan)"
    assert bool(hdr.is_locked(tbl.cur_hdr[1])), \
        "resolved entry's slot must be left alone"
    # a dead thread that never appended releases nothing
    tbl = _lock(tbl, 4, prio=0)
    tbl2 = wal.release_abandoned_locks(j, tbl, dead_tid=0)
    assert bool(hdr.is_locked(tbl2.cur_hdr[4]))


def test_wal_replay_wrapped_ring():
    """Bugfix regression: with ``used > capacity`` the old replay treated
    raw ring positions ``< used`` as valid — replaying overwritten entries
    and silently skipping nothing. The live window replays exactly the
    appends since the checkpoint, and a wrapped-past-unreplayed ring is a
    loud error, not a wrong table."""
    j = wal.init_journal(n_threads=3, capacity=4, n_slots=3, ws=1, width=2,
                         n_replicas=2)
    tbl, st, j, (ckpt_tbl, used_ckpt) = _run_workload(
        n_rounds=7, journal=j, ckpt_round=3)
    assert int(j.used[0]) == 7 > j.capacity  # the ring really wrapped
    recovered = wal.replay(j, ckpt_tbl, since=used_ckpt)
    np.testing.assert_array_equal(np.asarray(recovered.cur_data),
                                  np.asarray(tbl.cur_data))
    np.testing.assert_array_equal(
        np.asarray(hdr.commit_ts(recovered.cur_hdr)),
        np.asarray(hdr.commit_ts(tbl.cur_hdr)))
    # entries before the checkpoint were overwritten — replaying from a
    # fresh table (or any since that predates the window) must refuse
    fresh = mvcc.init_table(8, 2, n_old=2, n_overflow=4)
    with pytest.raises(ValueError, match="overwrote unreplayed"):
        wal.replay(j, fresh)
    with pytest.raises(ValueError, match="overwrote unreplayed"):
        wal.replay(j, ckpt_tbl, since=jnp.zeros((3,), jnp.int32))


@pytest.mark.parametrize("ts_a,ts_b", [
    # sum(T) of B wraps uint32 below A's — the old single-key order inverted
    ([0x7FFFFFFF, 0x7FFFFFFF], [0x80000000, 0x80000000]),
    # A's exact sum is 0xFFFFFFFF — the old SENTINEL — so A was dropped from
    # the replay entirely (sorted among the never-used entries)
    ([0xFFFFFFFE, 0x00000001], [0xFFFFFFFE, 0x00000002]),
])
def test_wal_replay_order_key_overflow(ts_a, ts_b):
    """Bugfix regression: the linear-extension key must not wrap. Entry B's
    logged T dominates A's, so B must replay after A and win the record —
    under the old uint32 ``sum(T)`` key it either sorted first (wrap) or
    collided with the not-committed sentinel."""
    j = wal.init_journal(n_threads=1, capacity=2, n_slots=2, ws=1, width=2,
                         n_replicas=1)
    tid = jnp.array([0], jnp.int32)
    for rnd, (ts, cts, val) in enumerate(
            [(ts_a, 1, 1), (ts_b, 2, 2)]):
        j = wal.append_intent(
            j, tid, jnp.array(ts, jnp.uint32),
            jnp.array([[0]], jnp.int32),
            hdr.pack(jnp.uint32(0), jnp.uint32(cts))[None, None],
            jnp.full((1, 1, 2), val, jnp.int32), jnp.array([[True]]),
            round_no=rnd)
        j = wal.append_outcome(j, tid, jnp.array([True]))
    fresh = mvcc.init_table(1, 2, n_old=2, n_overflow=2)
    recovered = wal.replay(j, fresh)
    assert int(hdr.commit_ts(recovered.cur_hdr[0])) == 2, \
        "dominated entry replayed last — order key wrapped or hit sentinel"
    np.testing.assert_array_equal(np.asarray(recovered.cur_data[0]), [2, 2])


def test_gc_snapshot_log_and_safe_vector():
    log = gc.init_log(4, n_slots=2)
    log = gc.take_snapshot(log, 100, jnp.array([1, 1], jnp.uint32))
    log = gc.take_snapshot(log, 200, jnp.array([3, 2], jnp.uint32))
    safe = gc.safe_vector(log, now=260, max_txn_time=100)
    np.testing.assert_array_equal(np.asarray(safe), [1, 1])
    safe2 = gc.safe_vector(log, now=400, max_txn_time=100)
    np.testing.assert_array_equal(np.asarray(safe2), [3, 2])


def test_gc_collect_marks_only_superseded():
    tbl = mvcc.init_table(2, 2, n_old=1, n_overflow=4)
    s = jnp.array([0], jnp.int32)
    for v in range(1, 5):  # versions 1..4 by thread 1; 1..3 spill to overflow
        out = mvcc.install(tbl, s, hdr.pack(jnp.uint32(1), jnp.uint32(v))[None],
                           jnp.full((1, 2), v, jnp.int32), jnp.array([True]))
        tbl = mvcc.version_mover(out.table)
    safe = jnp.array([0, 3], jnp.uint32)  # oldest live snapshot sees v3
    tbl2 = gc.collect(tbl, safe)
    # versions 1,2 in overflow must be doomed; v3 must survive
    ovf_cts = np.asarray(hdr.commit_ts(tbl2.ovf_hdr[0]))
    deleted = np.asarray(hdr.is_deleted(tbl2.ovf_hdr[0]))
    for cts, dead in zip(ovf_cts, deleted):
        if cts in (1, 2):
            assert dead
        if cts == 3:
            assert not dead
    # reads at the safe snapshot still succeed
    vr = mvcc.read_visible(tbl2, s, safe)
    assert bool(vr.found[0]) and int(hdr.commit_ts(vr.hdr[0])) == 3


def test_gc_take_snapshot_prefers_unused_slots():
    """Bugfix regression: while unused (−1) slots remain, take_snapshot must
    fill them — never evict a retained snapshot (the old argmin(times) did
    the right thing only because −1 happens to sort below every valid
    time)."""
    log = gc.init_log(4, n_slots=1)
    log = gc.take_snapshot(log, 10, jnp.array([1], jnp.uint32))
    log = gc.take_snapshot(log, 20, jnp.array([2], jnp.uint32))
    times = np.asarray(log.times)
    assert sorted(times[times >= 0]) == [10, 20]
    assert (times < 0).sum() == 2  # both retained, two slots still unused


def test_gc_snapshot_ring_full_wraparound():
    """Once the ring is full, each new snapshot evicts exactly the OLDEST
    retained one; after a full second lap only the newest S survive and
    safe_vector reflects them."""
    S = 4
    log = gc.init_log(S, n_slots=1)
    for t in range(10, 10 + 2 * S + 1):
        log = gc.take_snapshot(log, t, jnp.array([t], jnp.uint32))
        retained = np.asarray(log.times)
        retained = sorted(retained[retained >= 0])
        want = list(range(max(10, t - S + 1), t + 1))
        assert retained == want, (t, retained)
    # ring now holds times 15..18; at now=20, E=2 the newest qualifying
    # snapshot is t=18, so the safe vector is its vec
    safe = gc.safe_vector(log, now=20, max_txn_time=2)
    np.testing.assert_array_equal(np.asarray(safe), [18])


def _install_v(tbl, v):
    return mvcc.install(tbl, jnp.array([0], jnp.int32),
                        hdr.pack(jnp.uint32(1), jnp.uint32(v))[None],
                        jnp.full((1, 2), v, jnp.int32), jnp.array([True]))


def test_version_mover_reuse_only_stalls_until_collect():
    """§5.3 discipline: with reuse_only the mover never overwrites a live
    overflow version — it stalls, installs backpressure into aborts, and one
    collect+truncate unblocks the pipeline."""
    tbl = mvcc.init_table(1, 2, n_old=1, n_overflow=2)
    for v in (1, 2):
        out = _install_v(tbl, v)
        assert bool(out.installed[0])
        tbl = mvcc.version_mover(out.table, reuse_only=True)
    # ring now holds v0, v1 (both live); the next move must stall …
    out = _install_v(tbl, 3)
    assert bool(out.installed[0])
    tbl = mvcc.version_mover(out.table, reuse_only=True)
    ovf_cts = set(np.asarray(hdr.commit_ts(tbl.ovf_hdr[0])).tolist())
    assert ovf_cts == {0, 1}, "stalled mover must not clobber v0/v1"
    # … which blocks the NEXT install (old slot not reusable) → abort
    out = _install_v(tbl, 4)
    assert not bool(out.installed[0])
    # GC: safe snapshot sees v1 as newest ⇒ v0 reclaimed, truncated
    tbl = mvcc.compact_overflow(
        gc.collect(out.table, jnp.array([0, 1], jnp.uint32)))
    tbl = mvcc.version_mover(tbl, reuse_only=True)   # v2 → reclaimed slot
    out = _install_v(tbl, 4)                          # retry now succeeds
    assert bool(out.installed[0])
    tbl = out.table
    assert int(tbl.ovf_next[0]) < 2                   # ring ptr stays bounded
    # v2 must now be readable from the overflow region at its snapshot
    vr = mvcc.read_visible(tbl, jnp.array([0], jnp.int32),
                           jnp.array([0, 2], jnp.uint32))
    assert bool(vr.found[0]) and int(hdr.commit_ts(vr.hdr[0])) == 2
    assert bool(vr.from_ovf[0])


def test_compact_overflow_resets_deleted_slots_only():
    tbl = mvcc.init_table(1, 2, n_old=1, n_overflow=4)
    for v in (1, 2, 3):
        tbl = mvcc.version_mover(_install_v(tbl, v).table, reuse_only=True)
    tbl = gc.collect(tbl, jnp.array([0, 3], jnp.uint32))  # dooms v0, v1
    tbl2 = mvcc.compact_overflow(tbl)
    dead = np.asarray(hdr.is_deleted(tbl.ovf_hdr[0]))
    for k in range(4):
        if dead[k]:   # truncated to the zeroed reusable sentinel
            assert int(hdr.commit_ts(tbl2.ovf_hdr[0, k])) == 0
            assert int(np.asarray(tbl2.ovf_data[0, k]).sum()) == 0
            assert bool(hdr.is_deleted(tbl2.ovf_hdr[0, k]))
        else:         # live versions untouched
            np.testing.assert_array_equal(np.asarray(tbl2.ovf_hdr[0, k]),
                                          np.asarray(tbl.ovf_hdr[0, k]))
            np.testing.assert_array_equal(np.asarray(tbl2.ovf_data[0, k]),
                                          np.asarray(tbl.ovf_data[0, k]))
    # reads at any still-admissible snapshot are unchanged
    for vec in ([0, 2], [0, 3]):
        a = mvcc.read_visible(tbl, jnp.array([0]), jnp.array(vec, jnp.uint32))
        b = mvcc.read_visible(tbl2, jnp.array([0]), jnp.array(vec, jnp.uint32))
        assert bool(a.found[0]) == bool(b.found[0])
        np.testing.assert_array_equal(np.asarray(a.data), np.asarray(b.data))


@pytest.mark.skipif(len(compat.cpu_devices()) < 2,
                    reason="needs ≥2 CPU devices (run under the CI mesh "
                    "step's forced host devices)")
def test_distributed_gc_round_matches_single_shard():
    """The per-shard mesh sweep (store.distributed_gc_round) must be
    bit-identical to gc.gc_round over the whole pool, with every shard's
    snapshot log agreeing with the single-shard one."""
    import jax

    n = 2 if len(compat.cpu_devices()) < 4 else 4
    mesh = jax.sharding.Mesh(np.array(compat.cpu_devices()[:n]), ("mem",))
    n_records, width, T = 8 * n, 2, 3
    tbl_s = mvcc.init_table(n_records, width, n_old=1, n_overflow=4)
    o = VectorOracle(T)
    st = o.init()

    def fn(rh, rd, rts):
        return rd[:, :1, :].at[..., 0].add(1)

    import jax.random as jrandom
    key = jrandom.PRNGKey(3)
    # grow version history through real SI rounds (single copy)
    for r in range(6):
        key, sub = jrandom.split(key)
        slots = jrandom.randint(sub, (T, 2), 0, n_records)
        batch = si.TxnBatch(
            tid=jnp.arange(T, dtype=jnp.int32),
            read_slots=slots.astype(jnp.int32),
            read_mask=jnp.ones((T, 2), bool),
            write_ref=jnp.zeros((T, 1), jnp.int32),
            write_mask=jnp.ones((T, 1), bool))
        out = si.run_round(tbl_s, o, st, batch, fn)
        tbl_s, st = out.table, out.oracle_state
        tbl_s = mvcc.version_mover(tbl_s, reuse_only=True)

    tbl_d = store.shard_table(mesh, "mem", tbl_s)
    gc_fn = store.distributed_gc_round(mesh, "mem", shard_vector=False)
    log_s = gc.init_log(4, n_slots=T)
    logs_d = store.init_shard_logs(n, 4, n_slots=T)
    vec = st.vec
    for now in range(3):
        tbl_s, log_s = gc.gc_round(tbl_s, vec, log_s, now, 1)
        tbl_d, logs_d = gc_fn(tbl_d, vec, logs_d, now, 1)
    import jax
    for field in mvcc.VersionedTable._fields:
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(getattr(tbl_d, field))),
            np.asarray(getattr(tbl_s, field)), err_msg=field)
    for shard in range(n):
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(logs_d.times))[shard],
            np.asarray(log_s.times), err_msg=f"shard {shard} times")
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(logs_d.vecs))[shard],
            np.asarray(log_s.vecs), err_msg=f"shard {shard} vecs")
    # the sweep must have reclaimed something, or the equality is vacuous
    assert float(gc.reclaimable_fraction(tbl_s)) > 0.0


def test_gc_reclaimable_fraction_monotone():
    tbl = mvcc.init_table(2, 2, n_old=1, n_overflow=4)
    f0 = float(gc.reclaimable_fraction(tbl))
    s = jnp.array([0], jnp.int32)
    for v in range(1, 5):
        out = mvcc.install(tbl, s, hdr.pack(jnp.uint32(1), jnp.uint32(v))[None],
                           jnp.full((1, 2), v, jnp.int32), jnp.array([True]))
        tbl = mvcc.version_mover(out.table)
    tbl = gc.collect(tbl, jnp.array([0, 4], jnp.uint32))
    assert float(gc.reclaimable_fraction(tbl)) <= f0  # fresh init all deleted
