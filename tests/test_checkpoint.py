"""Checkpoint/restore: round trips, atomicity, elastic re-sharding."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import snapshot
from repro.train import optimizer as opt


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (8, 16), jnp.bfloat16),
            "b": jax.random.normal(k2, (16,), jnp.float32),
            "nested": {"u0": jnp.arange(12, dtype=jnp.int32)}}


def test_roundtrip_with_opt_state(tmp_path):
    params = _tree(jax.random.PRNGKey(0))
    ostate = opt.init(params)
    snapshot.save(str(tmp_path), params, ostate, step=42,
                  commit_vector=[3, 1, 4])
    p2, o2, meta = snapshot.restore(str(tmp_path), params, ostate)
    assert meta["step"] == 42
    assert meta["commit_vector"] == [3, 1, 4]
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert int(o2.step) == int(ostate.step)


def test_manifest_commit_is_atomic(tmp_path):
    """A crash mid-save must never leave a readable-but-partial manifest:
    the manifest is written last via os.replace."""
    params = _tree(jax.random.PRNGKey(1))
    snapshot.save(str(tmp_path), params, step=1)
    assert os.path.exists(tmp_path / "manifest.json")
    assert not os.path.exists(tmp_path / "manifest.json.tmp")
    man = json.load(open(tmp_path / "manifest.json"))
    # every referenced leaf file exists (manifest implies completeness)
    for leaf in man["leaves"].values():
        assert os.path.exists(tmp_path / leaf["file"])


def test_save_async_joins_and_matches(tmp_path):
    params = _tree(jax.random.PRNGKey(2))
    t = snapshot.save_async(str(tmp_path), params, step=7)
    t.join()
    p2, _, meta = snapshot.restore(str(tmp_path), params)
    assert meta["step"] == 7
    np.testing.assert_array_equal(
        np.asarray(params["w"], np.float32), np.asarray(p2["w"], np.float32))


def test_elastic_restore_new_sharding(tmp_path):
    """A checkpoint written under one topology re-lands under another —
    here: saved unsharded, restored with explicit single-device
    NamedShardings (the mesh-shape-agnostic path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    params = _tree(jax.random.PRNGKey(3))
    snapshot.save(str(tmp_path), params, step=3)
    mesh = jax.make_mesh((1,), ("data",))
    shard_tree = jax.tree.map(
        lambda x: NamedSharding(mesh, P(*([None] * x.ndim))), params)
    p2, _, _ = snapshot.restore(str(tmp_path), params,
                                shardings={"params": shard_tree})
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert isinstance(jax.tree.leaves(p2)[0].sharding, NamedSharding)
