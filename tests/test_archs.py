"""Per-architecture smoke + consistency tests (deliverable f).

For every assigned architecture: a REDUCED same-family config runs one
forward/train step on CPU (shape + finiteness asserts), and the serve path is
validated by the prefill+decode == full-forward consistency check — which
exercises KV caches, chunked mLSTM/mamba state carrying, SWA masks, softcaps,
prefix-LM masking and cross-attention.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, reduced
from repro.configs.base import SHAPES, shape_applies
from repro.models import build, transformer


def _batch(cfg, key, B=2, S=24, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
         "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
         "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.is_encdec:
        b["frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder_seq, cfg.d_model), dtype) * 0.1
    if cfg.is_prefix_lm:
        b["patches"] = jax.random.normal(
            ks[3], (B, cfg.prefix_len, cfg.d_model), dtype) * 0.1
    return b


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_smoke_forward_and_train_step(aid):
    cfg = reduced(get_arch(aid))
    m = build(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key, dtype=jnp.float32)
    batch = _batch(cfg, key)
    loss, grads = jax.value_and_grad(m.train_loss)(params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)
    # one SGD step changes the loss (training signal flows)
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype),
                           params, grads)
    loss2 = m.train_loss(params2, batch)
    assert np.isfinite(float(loss2)) and float(loss2) != float(loss)


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_prefill_decode_matches_full_forward(aid):
    """Teacher-forced decode must reproduce the parallel forward logits."""
    cfg = reduced(get_arch(aid))
    if cfg.n_experts:
        # dropless capacity so the parallel and decode paths are bit-equal
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    m = build(cfg)
    key = jax.random.PRNGKey(1)
    params = m.init(key, dtype=jnp.float32)
    B, S = 2, 16
    batch = _batch(cfg, key, B=B, S=S)

    # full parallel forward over all S tokens
    enc_out = None
    prefix_len = None
    inputs = batch["tokens"]
    if cfg.is_encdec:
        enc_out = transformer.encode(cfg, params, batch["frames"])
    if cfg.is_prefix_lm:
        x_tok = params["embed"][batch["tokens"]]
        inputs = jnp.concatenate(
            [batch["patches"].astype(x_tok.dtype), x_tok], 1)
        prefix_len = jnp.full((B,), cfg.prefix_len, jnp.int32)
    hidden, _ = transformer.forward_hidden(
        cfg, params, inputs, prefix_len=prefix_len, enc_out=enc_out)
    full_logits = hidden[:, -1].astype(jnp.float32) @ params["embed"].T

    # prefill S-1 tokens + decode the S-th
    pre = {k: v for k, v in batch.items() if k not in ("targets", "mask")}
    pre["tokens"] = batch["tokens"][:, : S - 1]
    _, cache = m.prefill(params, pre, max_len=S + cfg.prefix_len + 4)
    logits, cache = m.decode_step(params, cache, batch["tokens"][:, S - 1])

    from repro.models.common import softcap
    full_logits = np.asarray(softcap(full_logits, cfg.logit_softcap))
    np.testing.assert_allclose(np.asarray(logits), full_logits,
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("aid", ARCH_IDS)
def test_shape_applicability_rules(aid):
    cfg = get_arch(aid)
    ok_long, reason = shape_applies(cfg, SHAPES["long_500k"])
    pure_full_attn = aid in ("granite-moe-1b-a400m", "whisper-medium",
                             "granite-3-8b", "nemotron-4-15b",
                             "paligemma-3b")
    assert ok_long == (not pure_full_attn), (aid, reason)
    assert shape_applies(cfg, SHAPES["train_4k"])[0]
    assert shape_applies(cfg, SHAPES["decode_32k"])[0]


def test_param_counts_match_names():
    approx = {"mixtral-8x22b": 140e9, "jamba-v0.1-52b": 52e9,
              "gemma2-27b": 27e9, "granite-3-8b": 8e9,
              "nemotron-4-15b": 15e9, "h2o-danube-3-4b": 4e9,
              "paligemma-3b": 2.6e9}
    for aid, target in approx.items():
        n = get_arch(aid).n_params()
        assert 0.65 * target < n < 1.45 * target, (aid, n, target)
