"""Subprocess body for test_elasticity's online scale-out check.

DESIGN.md §4.3 end-to-end: the five-transaction TPC-C mix runs on a
4-way 'mem' mesh with the commit journal replicated across the memory
servers and a checkpoint taken after every GC sweep.  Mid-run a
``MeshGrowth`` doubles the mesh to 8 memory servers — the scale-out is a
planned §6.2 failover: the last checkpoint is restored, the journal is
replayed over the migration window onto it, the moved record ranges and
timestamp-vector slots take the replayed reconstruction, the §5.2
directory / journal replicas / §5.3 snapshot logs are repartitioned over
the grown mesh, the executors are rebuilt and the workload resumes.

The expanded run must be bit-identical to a run launched at 8 shards
from the same seeds — installed versions (current + old + overflow), the
timestamp vector, per-type commit/abort/retry counts, GC telemetry and
op profiles — in BOTH pool layouts (table_major and the §7.3
warehouse_major).  Growing the mesh is a placement change, not a
semantics change.

The config deliberately uses 12 execution threads: 12 divides over the
4-shard mesh but NOT over the 8-shard one, so the expansion crosses a
non-dividing partitioned-vector boundary (``store.pad_vector``) —
exercising the scale-out path this PR fixed for 3→5-style growth.
"""
import os
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import numpy as np

from repro.core import locality, store
from repro.core.tsoracle import PartitionedVectorOracle
from repro.db import tpcc, workload

CFG = dict(n_warehouses=4, customers_per_district=8, n_items=64,
           n_threads=12, orders_per_thread=16, dist_degree=30.0)
ROUNDS = 6
GROW = tpcc.MeshGrowth(grow_round=3, new_shards=8)
GC = dict(gc_interval=2, max_txn_time=1)


def setup(cfg, n_shards):
    """A freshly loaded ``n_shards``-way deployment with journalling."""
    mesh = jax.make_mesh((n_shards,), ("mem",))
    oracle = PartitionedVectorOracle(cfg.n_threads, n_parts=n_shards)
    lay, st = tpcc.init_tpcc(cfg, oracle, jax.random.PRNGKey(0))
    engine = tpcc.make_mixed_engine(cfg, lay, mesh, "mem", oracle,
                                    shard_vector=True, with_journal=True)
    st = tpcc.distribute_state(engine, st)
    jnl = tpcc.make_journal(cfg, oracle, capacity_rounds=ROUNDS + 2,
                            n_replicas=engine.n_shards)
    jnl = store.shard_journal(mesh, "mem", jnl)
    return oracle, lay, st, engine, jnl


def assert_same_state(layout, lay, n_slots, st_a, st_b):
    # the two runs pad the pool for different shard counts mid-history, so
    # equality is over the real records/slots — padding carries no semantics
    R = lay.catalog.total_records
    for field in tpcc.mvcc.VersionedTable._fields:
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(getattr(st_a.nam.table, field)))[:R],
            np.asarray(jax.device_get(getattr(st_b.nam.table, field)))[:R],
            err_msg=f"{layout}:{field}")
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(st_a.nam.oracle_state.vec))[:n_slots],
        np.asarray(jax.device_get(st_b.nam.oracle_state.vec))[:n_slots],
        err_msg=f"{layout}:vec")
    np.testing.assert_array_equal(np.asarray(st_a.nam.extends.cursor),
                                  np.asarray(st_b.nam.extends.cursor))
    np.testing.assert_array_equal(np.asarray(st_a.hist_cursor),
                                  np.asarray(st_b.hist_cursor))
    for leaf_a, leaf_b in zip(jax.tree.leaves(st_a.order_index),
                              jax.tree.leaves(st_b.order_index)):
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(leaf_a)),
            np.asarray(jax.device_get(leaf_b)), err_msg=f"{layout}:index")


def run_layout(layout, key_addressed=False):
    cfg = tpcc.TPCCConfig(layout=layout, key_addressed=key_addressed, **CFG)
    home = locality.thread_homes(cfg.n_threads, cfg.n_warehouses)

    # the reference: born at 8 shards, never grows
    oracle, lay, st0, engine, jnl = setup(cfg, GROW.new_shards)
    with tempfile.TemporaryDirectory() as d:
        st_ref, ms_ref = tpcc.run_mixed_rounds(
            cfg, lay, st0, oracle, jax.random.PRNGKey(9), ROUNDS,
            home_w=home, engine=engine, journal=jnl, checkpoint_dir=d, **GC)
    assert ms_ref.growth == ()

    # the live system: born at 4 shards, grown to 8 mid-mix
    oracle, lay, st1, engine, jnl = setup(cfg, 4)
    with tempfile.TemporaryDirectory() as d:
        st_exp, ms_exp = tpcc.run_mixed_rounds(
            cfg, lay, st1, oracle, jax.random.PRNGKey(9), ROUNDS,
            home_w=home, engine=engine, journal=jnl, checkpoint_dir=d,
            growth=GROW, **GC)

    (rep,) = ms_exp.growth
    assert rep.grow_round == GROW.grow_round
    assert (rep.old_shards, rep.new_shards) == (4, GROW.new_shards)
    # the expansion landed mid-run: the migration checkpoint predates the
    # grow round and committed work since it really was replayed from the
    # journal; record ranges really moved to the joining servers
    assert 0 <= rep.checkpoint_round < rep.grow_round, rep
    assert rep.replayed_entries > 0, rep
    assert rep.moved_slots > 0, rep
    assert rep.migration_seconds > 0, rep
    if key_addressed:   # the §5.2 directory really was repartitioned
        assert rep.moved_buckets > 0, rep

    assert_same_state(layout, lay, oracle.n_slots, st_ref, st_exp)
    for name in workload.TXN_TYPES:
        assert ms_ref.attempts[name] == ms_exp.attempts[name], (layout, name)
        assert ms_ref.commits[name] == ms_exp.commits[name], (layout, name)
        assert ms_ref.retries[name] == ms_exp.retries[name], (layout, name)
        for f, a, b in zip(tpcc.si.OpCounts._fields, ms_exp.ops[name],
                           ms_ref.ops[name]):
            assert float(a) == float(b), (layout, name, f)
    assert ms_ref.delivered == ms_exp.delivered
    assert ms_ref.snapshot_misses == ms_exp.snapshot_misses
    assert ms_ref.contention_aborts == ms_exp.contention_aborts
    assert ms_ref.gc_sweeps == ms_exp.gc_sweeps > 0
    assert ms_ref.ovf_peak == ms_exp.ovf_peak
    assert ms_ref.reclaim_traj == ms_exp.reclaim_traj
    assert ms_exp.total_commits > 0
    print(f"{layout}: grew {rep.old_shards}→{rep.new_shards} at round "
          f"{rep.grow_round} (checkpoint {rep.checkpoint_round}, "
          f"{rep.replayed_entries} replayed, {rep.moved_slots} slots moved, "
          f"{rep.moved_buckets} buckets moved) — expanded == born-large")


def main():
    assert len(jax.devices()) == 8, jax.devices()
    for layout in ("table_major", "warehouse_major"):
        run_layout(layout)
    # once more through the §5.2 key-addressed read path: the expansion must
    # also repartition the hash directory's bucket ranges
    run_layout("table_major", key_addressed=True)
    print("ELASTICITY_EQUIV_OK")


if __name__ == "__main__":
    main()
