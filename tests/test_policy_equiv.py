"""The §Perf opt policy must not change model semantics.

Runs tests/_policy_equiv_check.py in a subprocess (it needs 16 placeholder
devices, which must not leak into this process's jax).
"""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_policy_equivalence_16dev():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    out = subprocess.run(
        [sys.executable, os.path.join(root, "tests",
                                      "_policy_equiv_check.py")],
        capture_output=True, text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "POLICY-EQUIV-ALL-OK" in out.stdout
