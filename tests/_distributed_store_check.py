"""Subprocess body for test_distributed_store: runs with 8 host devices.

Executes the same workload twice — single-device si.run_round vs. the
shard_map distributed_round over an 8-way 'mem' mesh — and asserts identical
committed sets and identical final table state (the distribution layer must
be semantics-preserving).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mvcc, si, store
from repro.core.tsoracle import VectorOracle


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((8,), ("mem",))
    n_records, width, n_threads = 64, 4, 16
    shard_records = n_records // 8
    oracle = VectorOracle(n_threads)

    def compute_fn(rh, rd, vec, aux):
        return rd[:, :1, :].at[..., 0].add(1)

    round_fn, _ = store.distributed_round(mesh, "mem", oracle, compute_fn,
                                          shard_records)

    tbl_d = store.shard_table(mesh, "mem",
                              mvcc.init_table(n_records, width, 2, 2))
    tbl_s = mvcc.init_table(n_records, width, 2, 2)
    st = oracle.init()
    vec_d = st.vec
    key = jax.random.PRNGKey(7)
    for rnd in range(6):
        key, sub = jax.random.split(key)
        slots = jax.random.randint(sub, (n_threads, 2), 0, n_records,
                                   dtype=jnp.int32)
        batch = si.TxnBatch(
            tid=jnp.arange(n_threads, dtype=jnp.int32),
            read_slots=slots,
            read_mask=jnp.ones((n_threads, 2), bool),
            write_ref=jnp.zeros((n_threads, 1), jnp.int32),
            write_mask=jnp.ones((n_threads, 1), bool),
        )
        tbl_d, vec_d, dout = round_fn(tbl_d, vec_d, batch, None)
        out = si.run_round(tbl_s, oracle, st, batch,
                           lambda rh, rd, vec: compute_fn(rh, rd, vec, None))
        tbl_s, st = out.table, out.oracle_state
        np.testing.assert_array_equal(np.asarray(dout.committed),
                                      np.asarray(out.committed),
                                      err_msg=str(rnd))
        tbl_s = mvcc.version_mover(tbl_s)
        # the version-mover is per-record elementwise, so it runs directly on
        # the sharded table (XLA preserves the record-axis sharding)
        tbl_d = jax.jit(mvcc.version_mover)(tbl_d)
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(tbl_d.cur_data)),
            np.asarray(tbl_s.cur_data))
    np.testing.assert_array_equal(np.asarray(vec_d), np.asarray(st.vec))
    print("DISTRIBUTED_OK")


if __name__ == "__main__":
    main()
