"""Tests: hash table, range index, catalog, extends, locality, cost model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import catalog as cat, hashtable as ht, locality, netmodel
from repro.core import rangeindex as ri
from repro.core import store as store_mod
from repro.core.tsoracle import VectorOracle


# ----------------------------------------------------------- hash table ----
def test_hashtable_insert_lookup_roundtrip():
    t = ht.init(64)
    keys = jnp.array([3, 17, 99, 3 + 64], jnp.uint32)  # 3 and 67 may collide
    vals = jnp.array([30, 170, 990, 670], jnp.int32)
    t, placed = ht.insert(t, keys, vals)
    assert int((placed >= 0).sum()) == 4
    got, found = ht.lookup(t, keys)
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(got), [30, 170, 990, 670])


def test_hashtable_missing_key():
    t = ht.init(32)
    t, _ = ht.insert(t, jnp.array([5], jnp.uint32), jnp.array([1], jnp.int32))
    _, found = ht.lookup(t, jnp.array([6], jnp.uint32))
    assert not bool(found[0])


def test_hashtable_update_in_place():
    t = ht.init(32)
    t, _ = ht.insert(t, jnp.array([5], jnp.uint32), jnp.array([1], jnp.int32))
    t, _ = ht.insert(t, jnp.array([5], jnp.uint32), jnp.array([2], jnp.int32))
    got, found = ht.lookup(t, jnp.array([5], jnp.uint32))
    assert bool(found[0]) and int(got[0]) == 2


def test_hashtable_batch_duplicate_keys_single_winner():
    t = ht.init(32)
    t, placed = ht.insert(t, jnp.array([7, 7], jnp.uint32),
                          jnp.array([10, 20], jnp.int32))
    got, found = ht.lookup(t, jnp.array([7], jnp.uint32))
    assert bool(found[0]) and int(got[0]) in (10, 20)


def test_hashtable_fills_to_capacity():
    n = 16
    t = ht.init(n)
    keys = jnp.arange(n, dtype=jnp.uint32) * 37 + 1
    t, placed = ht.insert(t, keys, jnp.arange(n, dtype=jnp.int32),
                          max_probes=n)
    assert int((placed >= 0).sum()) == n
    got, found = ht.lookup(t, keys, max_probes=n)
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(got), np.arange(n))
    # the store-level directory build must surface probe exhaustion loudly:
    # same keys into the same capacity is fine …
    d = store_mod.build_directory(keys, jnp.arange(n, dtype=jnp.int32), n,
                                  max_probes=n)
    got, found = ht.lookup(d, keys, max_probes=n)
    assert bool(found.all())
    # … one key beyond capacity (or a too-short probe budget) is an error,
    # never a silently dropped entry (insert's placed_at == -1)
    over = jnp.concatenate([keys, jnp.array([9999], jnp.uint32)])
    with pytest.raises(ValueError, match="probe chains exceeded"):
        store_mod.build_directory(over, jnp.arange(n + 1, dtype=jnp.int32),
                                  n, max_probes=n + 1)
    # two keys sharing a home bucket cannot both place with max_probes=1
    collide = [k for k in range(1, 2000)
               if (k * 2654435769 % (1 << 32)) % n == 0][:2]
    with pytest.raises(ValueError, match="probe chains exceeded"):
        store_mod.build_directory(jnp.asarray(collide, jnp.uint32),
                                  jnp.array([0, 1], jnp.int32), n,
                                  max_probes=1)


def test_hashtable_delete_lookup_reinsert():
    """Regression: delete-then-lookup used to return found=True, val=-1 —
    any caller gathering with that slot silently read the last pool record."""
    t = ht.init(32)
    t, _ = ht.insert(t, jnp.array([5, 9], jnp.uint32),
                     jnp.array([50, 90], jnp.int32))
    t, was_there = ht.delete(t, jnp.array([5], jnp.uint32))
    assert bool(was_there[0])
    got, found = ht.lookup(t, jnp.array([5, 9], jnp.uint32))
    assert not bool(found[0]), "deleted key must report found=False"
    assert bool(found[1]) and int(got[1]) == 90
    # the invalidated entry still terminates the probe chain and supports
    # update-in-place reinsertion
    t, placed = ht.insert(t, jnp.array([5], jnp.uint32),
                          jnp.array([55], jnp.int32))
    assert int(placed[0]) >= 0
    got, found = ht.lookup(t, jnp.array([5], jnp.uint32))
    assert bool(found[0]) and int(got[0]) == 55


def test_hashtable_lookup_shard_matches_lookup():
    """Partitioned probing (every shard walks the global probe sequence over
    its resident bucket range) reconstructs lookup() bit-exactly — including
    deleted entries and missing keys."""
    B, n_shards = 64, 4
    t = ht.init(B)
    keys = jnp.arange(1, 40, dtype=jnp.uint32) * 97
    t, _ = ht.insert(t, keys, jnp.arange(39, dtype=jnp.int32), max_probes=B)
    t, _ = ht.delete(t, keys[5:9])
    qs = jnp.concatenate([keys, jnp.array([7, 100000], jnp.uint32)])
    want_v, want_f = ht.lookup(t, qs, max_probes=B)
    per = B // n_shards
    vsum = jnp.zeros(qs.shape, jnp.int32)
    khit = jnp.zeros(qs.shape, bool)
    for s in range(n_shards):
        v, h = ht.lookup_shard(t.keys[s * per:(s + 1) * per],
                               t.vals[s * per:(s + 1) * per], qs, s * per,
                               B, max_probes=B)
        vsum = vsum + v
        khit = khit | h
    got_f = khit & (vsum >= 0)
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))
    np.testing.assert_array_equal(np.asarray(jnp.where(got_f, vsum, -1)),
                                  np.asarray(jnp.where(want_f, want_v, -1)))
    # owner of each key's home bucket agrees with partition_of
    owners = ht.partition_of(keys, B, n_shards)
    assert int(jnp.max(owners)) < n_shards and int(jnp.min(owners)) >= 0


# ----------------------------------------------------------- range index ----
def test_rangeindex_scan_and_insert():
    idx = ri.build(jnp.array([10, 20, 30, 40], jnp.uint32),
                   jnp.array([1, 2, 3, 4], jnp.int32), capacity=16)
    k, v, n = ri.range_scan(idx, jnp.array([15]), jnp.array([45]),
                            max_results=8)
    assert int(n[0]) == 3
    np.testing.assert_array_equal(np.asarray(v[0, :3]), [2, 3, 4])
    idx = ri.insert(idx, jnp.array([25], jnp.uint32),
                    jnp.array([9], jnp.int32))
    k, v, n = ri.range_scan(idx, jnp.array([20]), jnp.array([31]),
                            max_results=8)
    assert int(n[0]) == 3
    assert 9 in np.asarray(v[0])


def test_rangeindex_merge_preserves_entries():
    idx = ri.build(jnp.array([5], jnp.uint32), jnp.array([50], jnp.int32),
                   capacity=8)
    idx = ri.insert(idx, jnp.array([3], jnp.uint32), jnp.array([30], jnp.int32))
    idx = ri.merge(idx)
    k, v, n = ri.range_scan(idx, jnp.array([0]), jnp.array([10]),
                            max_results=4)
    assert int(n[0]) == 2
    np.testing.assert_array_equal(np.asarray(v[0, :2]), [30, 50])


def test_rangeindex_lookup_max_below():
    idx = ri.build(jnp.array([10, 20, 30], jnp.uint32),
                   jnp.array([1, 2, 3], jnp.int32), capacity=8)
    k, v, found = ri.lookup_max_below(idx, jnp.array([25]))
    assert bool(found[0]) and int(k[0]) == 20 and int(v[0]) == 2
    _, _, found0 = ri.lookup_max_below(idx, jnp.array([10]))
    assert not bool(found0[0])


# -------------------------------------------------------------- catalog ----
def test_catalog_layout_and_versioning():
    c = cat.Catalog(n_servers=4)
    a = c.create_table("a", count=100, width=4)
    b = c.create_table("b", count=50, width=8)
    assert a.base == 0 and b.base == 100 and c.total_records == 150
    assert int(b.slot(7)) == 107
    st = c.init_state()
    cached = st
    st2 = c.alter(st, "b")
    assert bool(c.needs_refresh(st2, cached).any())
    assert not bool(c.needs_refresh(st, cached).any())


def test_extend_allocator_no_conflicts():
    ext = store_mod.ExtendState(cursor=jnp.zeros((4, 1), jnp.int32))
    slots = []
    for tid in range(4):
        ext, first = store_mod.allocate(ext, tid, 0, 3, region_base=1000,
                                        extend_size=10, threads=4)
        slots.append(int(first))
    assert slots == [1000, 1010, 1020, 1030]
    ext, nxt = store_mod.allocate(ext, 0, 0, 1, 1000, 10, 4)
    assert int(nxt) == 1003  # cursor advanced by the earlier n=3


# ------------------------------------------------------------- locality ----
def test_local_fraction():
    p = locality.Placement(n_servers=4, shard_records=100)
    txn_server = jnp.array([0, 1], jnp.int32)
    slots = jnp.array([[5, 150], [150, 350]], jnp.int32)
    mask = jnp.ones((2, 2), bool)
    f = locality.local_fraction(p, txn_server, slots, mask)
    assert abs(float(f) - 0.5) < 1e-6


# ------------------------------------------------------------- netmodel ----
def test_netmodel_anchor_points():
    """The calibrated model must land on the paper's anchors (±20 %)."""
    m = netmodel
    assert 24e3 < m.intro_example_throughput() < 34e3          # ~29 k (§1.1)
    naive = m.oracle_throughput("naive", 1, 10)
    assert 1.5e6 < naive < 2.5e6                               # ~2 M
    basic = m.oracle_throughput("vector", 8, 20)
    assert 16e6 < basic < 25e6                                 # ~20 M
    bg = m.oracle_throughput("vector_bg", 8, 20)
    assert 30e6 < bg < 42e6                                    # ~36 M
    comp = m.oracle_throughput("vector_compressed", 8, 20)
    assert 64e6 < comp < 96e6                                  # ~80 M
    both = m.oracle_throughput("vector_both", 8, 20)
    assert 108e6 < both < 170e6                                # ~135 M


def test_netmodel_naive_degrades_with_clients():
    a = netmodel.oracle_throughput("naive", 2, 10)
    b = netmodel.oracle_throughput("naive", 8, 20)
    assert b < a  # paper: >20 clients the naive oracle degrades


def test_netmodel_namdb_scales_linearly():
    p = netmodel.TxnProfile(reads=23, cas=11, installs=11, bytes_read=4000,
                            bytes_written=3000)
    t1 = netmodel.namdb_throughput(p, 14, 60, abort_rate=0.02)
    t2 = netmodel.namdb_throughput(p, 28, 60, abort_rate=0.02)
    t3 = netmodel.namdb_throughput(p, 56, 60, abort_rate=0.02)
    assert 1.8 < t2 / t1 < 2.2 and 1.8 < t3 / t2 < 2.2


def test_netmodel_traditional_degrades():
    p = netmodel.TxnProfile(reads=23, cas=11, installs=11, bytes_read=4000,
                            bytes_written=3000)
    ts = [netmodel.traditional_throughput(p, n, 60, 0.02)
          for n in (2, 10, 56)]
    assert ts[1] < 10 * ts[0]          # sub-linear well before 10 machines
    nam = netmodel.namdb_throughput(p, 56, 60, 0.02)
    assert nam > 5 * ts[2]             # NAM-DB wins by a wide margin at 56


def test_netmodel_locality_bonus_moderate():
    """§7.3: locality buys ~30 %, not orders of magnitude."""
    p = netmodel.TxnProfile(reads=23, cas=11, installs=11, bytes_read=4000,
                            bytes_written=3000)
    t0 = netmodel.namdb_throughput(p, 8, 20, 0.02, local_fraction=0.0)
    t9 = netmodel.namdb_throughput(p, 8, 20, 0.02, local_fraction=0.9)
    assert 1.1 < t9 / t0 < 2.0


def test_hstore_anchors():
    assert abs(netmodel.hstore_like_throughput(0.0) - 11000) < 1
    assert abs(netmodel.hstore_like_throughput(1.0) - 900) < 1


# --------------------------------- non-dividing shard counts (scale-out) ----
def test_pad_vector_non_dividing():
    """A 3→5-style expansion leaves the timestamp vector length
    non-divisible by the shard count; ``pad_vector`` must square it off with
    zero slots (and be the identity when it already divides)."""
    vec = jnp.arange(1, 13, dtype=jnp.uint32)            # 12 slots
    padded, n = store_mod.pad_vector(vec, 8)
    assert n == 16 and padded.shape == (16,)
    np.testing.assert_array_equal(np.asarray(padded[:12]), np.asarray(vec))
    np.testing.assert_array_equal(np.asarray(padded[12:]),
                                  np.zeros(4, np.uint32))
    same, n = store_mod.pad_vector(vec, 4)
    assert n == 12 and same is vec


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs a >=2 device mesh")
def test_shard_vector_non_dividing_round_matches_reference():
    """Regression: ``distributed_round(shard_vector=True)`` used to REQUIRE
    ``n_slots % n_shards == 0``, so a mesh grown to a non-dividing size
    (e.g. 3→5 memory servers) could not host the partitioned T_R at all.
    With zero-padding the partitioned vector must stay bit-identical to the
    single-shard reference on the real slots."""
    from repro.core import mvcc, si
    mesh = jax.make_mesh((2,), ("mem",))
    n_records, width, n_threads = 16, 4, 3      # 3 slots over 2 shards
    oracle = VectorOracle(n_threads)

    def compute_fn(rh, rd, vec, aux):
        return rd[:, :1, :].at[..., 0].add(1)

    round_fn, _ = store_mod.distributed_round(
        mesh, "mem", oracle, compute_fn, n_records // 2, shard_vector=True)
    tbl_d = store_mod.shard_table(mesh, "mem",
                                  mvcc.init_table(n_records, width, 2, 2))
    tbl_s = mvcc.init_table(n_records, width, 2, 2)
    st = oracle.init()
    vec_d = store_mod.shard_vector(mesh, "mem", st.vec)
    assert vec_d.shape == (4,)       # padded to the 2-shard multiple
    key = jax.random.PRNGKey(3)
    for rnd in range(4):
        key, sub = jax.random.split(key)
        slots = jax.random.randint(sub, (n_threads, 2), 0, n_records,
                                   dtype=jnp.int32)
        batch = si.TxnBatch(
            tid=jnp.arange(n_threads, dtype=jnp.int32),
            read_slots=slots,
            read_mask=jnp.ones((n_threads, 2), bool),
            write_ref=jnp.zeros((n_threads, 1), jnp.int32),
            write_mask=jnp.ones((n_threads, 1), bool))
        tbl_d, vec_d, dout = round_fn(tbl_d, vec_d, batch, None)
        out = si.run_round(tbl_s, oracle, st, batch,
                           lambda rh, rd, vec: compute_fn(rh, rd, vec, None))
        tbl_s, st = out.table, out.oracle_state
        np.testing.assert_array_equal(np.asarray(dout.committed),
                                      np.asarray(out.committed),
                                      err_msg=str(rnd))
    got = np.asarray(jax.device_get(vec_d))
    np.testing.assert_array_equal(got[:3], np.asarray(st.vec))
    np.testing.assert_array_equal(got[3:], np.zeros(1, np.uint32))
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(tbl_d.cur_data)), np.asarray(tbl_s.cur_data))


def test_moved_slots_expansion_mask():
    """Scale-out migration set: exactly the slots whose owning memory server
    changes between the old and new range partitions."""
    old = locality.Placement(n_servers=2, shard_records=8)   # 16 slots
    new = locality.Placement(n_servers=4, shard_records=4)
    moved = np.asarray(locality.moved_slots(old, new, 16))
    s = np.arange(16)
    np.testing.assert_array_equal(moved, (s // 8) != (s // 4))
    assert moved.sum() == 12 and not moved[:4].any()


def test_moved_buckets_expansion_mask():
    """§5.2 directory repartition: buckets whose owner changes when the mesh
    grows (non-dividing new count exercises the ceil-partition)."""
    mb = np.asarray(ht.moved_buckets(64, 2, 3))
    b = np.arange(64)
    old_per, new_per = 32, -(-64 // 3)
    np.testing.assert_array_equal(mb, (b // old_per) != (b // new_per))
    assert not np.asarray(ht.moved_buckets(64, 4, 4)).any()
