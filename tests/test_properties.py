"""Hypothesis property tests on the system's core invariants.

SI protocol (paper §3/§4/§5):
  P1  conservation — balance-transfer workloads never create or destroy
      value, whatever the conflict pattern (atomicity under any interleave).
  P2  monotone visibility — the timestamp vector only moves forward, and a
      committed write is visible to every later snapshot until overwritten.
  P3  header round-trip — pack/unpack of ⟨thread, cts, moved, deleted,
      locked⟩ is lossless for all field values.
  P4  write-write exclusion — per record, at most ONE transaction of a
      round commits an update to it.
  P5  visible read returns the newest version ≤ snapshot — against a
      brute-force reference over the full version history.

GC (paper §5.3):
  P6  GC safety — ``gc.collect`` at the safe vector never marks a version
      that is the newest visible one at ANY admissible snapshot (any
      snapshot ≥ the safe vector elementwise, i.e. any snapshot a live
      transaction younger than E could still hold): reads at every such
      snapshot are unchanged by the sweep (+ lazy truncation).
  P7  GC liveness — repeated install → move(reuse_only) → collect →
      truncate cycles keep the overflow ring pointer bounded in [0, KO),
      keep installs succeeding (no permanent stall), and actually REUSE
      slots rather than exhausting them.

Recovery (paper §6.2):
  P8  durability — killing the memory server at ANY round of a journalled
      TPC-C mix (optionally with undetermined in-flight intents holding
      locks), then restoring the last checkpoint and replaying the
      journal, yields a run bit-identical to one that never crashed.

Elasticity (DESIGN.md §4.3):
  P9  scale-out transparency — growing the mesh at ANY round of a
      journalled mixed run, with whatever in-flight/retry-queue state that
      round carries, never changes any already-committed version or any
      visible read at an admissible snapshot: the expanded run is
      bit-identical to one born at the larger shard count (needs ≥4
      devices; CI's mesh step forces 8).
"""
import tempfile

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; the seeded-random "
    "equivalents live in tests/test_si_invariants.py")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gc, header as hdr, mvcc, si
from repro.core.tsoracle import VectorOracle

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=20,
    suppress_health_check=[hypothesis.HealthCheck.too_slow,
                           hypothesis.HealthCheck.data_too_large])
hypothesis.settings.load_profile("ci")


# ---------------------------------------------------------------- P3 ------
@given(tid=st.integers(0, 2**29 - 1), cts=st.integers(0, 2**32 - 1),
       moved=st.booleans(), deleted=st.booleans(), locked=st.booleans())
@settings(max_examples=25, deadline=None)
def test_header_roundtrip(tid, cts, moved, deleted, locked):
    h = hdr.pack(jnp.uint32(tid), jnp.uint32(cts), moved=moved,
                 deleted=deleted, locked=locked)
    assert int(hdr.thread_id(h)) == tid
    assert int(hdr.commit_ts(h)) == cts
    assert bool(hdr.is_moved(h)) == moved
    assert bool(hdr.is_deleted(h)) == deleted
    assert bool(hdr.is_locked(h)) == locked
    # lock toggle is involutive and does not disturb other fields
    h2 = hdr.with_lock(hdr.with_lock(h, True), locked)
    assert int(hdr.thread_id(h2)) == tid and int(hdr.commit_ts(h2)) == cts
    assert bool(hdr.is_locked(h2)) == locked


# ---------------------------------------------------------- P1 + P2 + P4 --
@st.composite
def transfer_rounds(draw):
    n_acc = draw(st.integers(4, 24))
    T = draw(st.integers(2, 12))
    n_rounds = draw(st.integers(1, 5))
    seed = draw(st.integers(0, 2**31 - 1))
    return n_acc, T, n_rounds, seed


@given(transfer_rounds())
@settings(max_examples=12, deadline=None)
def test_si_conservation_and_monotonicity(params):
    n_acc, T, n_rounds, seed = params
    table = mvcc.init_table(n_acc, payload_width=1, n_old=4)
    table = table._replace(
        cur_data=jnp.full((n_acc, 1), 100, jnp.int32))
    oracle = VectorOracle(T)
    state = oracle.init()
    key = jax.random.PRNGKey(seed)
    prev_vec = np.asarray(state.vec).copy()

    for rnd in range(n_rounds):
        key, k1, k2 = jax.random.split(key, 3)
        src = jax.random.randint(k1, (T,), 0, n_acc)
        dst = (src + 1 + jax.random.randint(k2, (T,), 0, n_acc - 1)) % n_acc
        batch = si.TxnBatch(
            tid=jnp.arange(T, dtype=jnp.int32),
            read_slots=jnp.stack([src, dst], 1).astype(jnp.int32),
            read_mask=jnp.ones((T, 2), bool),
            write_ref=jnp.broadcast_to(jnp.arange(2, dtype=jnp.int32),
                                       (T, 2)),
            write_mask=jnp.ones((T, 2), bool))

        def xfer(rh, rd, vec):
            out = rd.astype(jnp.int32)
            out = out.at[:, 0, 0].add(-7)
            out = out.at[:, 1, 0].add(+7)
            return out

        res = si.run_round(table, oracle, state, batch, xfer)
        table, state = res.table, res.oracle_state

        # P1: conservation
        assert int(table.cur_data[:, 0].sum()) == n_acc * 100
        # P2: vector moves only forward
        vec = np.asarray(state.vec)
        assert (vec >= prev_vec).all()
        prev_vec = vec.copy()
        # P4: all current versions unlocked after the round
        assert not bool(np.asarray(hdr.is_locked(table.cur_hdr)).any())

        # P4b: committed writers of one record are unique per round
        comm = np.asarray(res.committed)
        w_slots = np.stack([np.asarray(src), np.asarray(dst)], 1)
        touched = {}
        for t in range(T):
            if not comm[t]:
                continue
            for s in w_slots[t]:
                assert s not in touched, "two commits updated one record"
                touched[s] = t


# ---------------------------------------------------------------- P5 ------
@given(st.integers(0, 2**31 - 1), st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_visible_read_matches_bruteforce(seed, n_rounds):
    """Install versions at known cts; read_visible must return the newest
    version whose ⟨thread, cts⟩ is ≤ the snapshot vector."""
    T, n_rec = 4, 6
    table = mvcc.init_table(n_rec, payload_width=1, n_old=4)
    table = table._replace(cur_data=jnp.zeros((n_rec, 1), jnp.int32))
    oracle = VectorOracle(T)
    state = oracle.init()
    key = jax.random.PRNGKey(seed)
    history = {r: [(0, 0, 0)] for r in range(n_rec)}   # (thread,cts,value)

    for rnd in range(n_rounds):
        key, k1 = jax.random.split(key)
        slot = jax.random.randint(k1, (T,), 0, n_rec)
        batch = si.TxnBatch(
            tid=jnp.arange(T, dtype=jnp.int32),
            read_slots=slot[:, None].astype(jnp.int32),
            read_mask=jnp.ones((T, 1), bool),
            write_ref=jnp.zeros((T, 1), jnp.int32),
            write_mask=jnp.ones((T, 1), bool))

        def bump(rh, rd, vec, _r=rnd):
            return rd.astype(jnp.int32) + 1 + _r

        res = si.run_round(table, oracle, state, batch, bump)
        comm = np.asarray(res.committed)
        svec = np.asarray(res.oracle_state.vec)
        for t in range(T):
            if comm[t]:
                s = int(slot[t])
                val = int(res.table.cur_data[s, 0])
                history[s].append((t, int(svec[t]), val))
        table, state = res.table, res.oracle_state

    # now read every record at the final snapshot and compare to brute force
    vec = jnp.asarray(np.asarray(state.vec))
    vr = mvcc.read_visible(table, jnp.arange(n_rec, dtype=jnp.int32), vec)
    for r in range(n_rec):
        visible = [v for (t, c, v) in history[r]
                   if c <= int(vec[t])]
        newest = history[r][-1]
        # current version is always the newest committed; it must be visible
        # at the full final snapshot and equal the stored current data
        assert bool(vr.found[r])
        assert int(vr.data[r, 0]) == newest[2]
        assert newest[2] in visible


# ---------------------------------------------------------- P6 + P7 ------
def _run_si_with_snapshots(seed, n_rounds, T=4, n_rec=6, n_old=2, ko=8):
    """Drive real SI rounds, logging T_R after each round (wall-clock = round
    index). Returns (table, vec history list, oracle state)."""
    table = mvcc.init_table(n_rec, payload_width=1, n_old=n_old,
                            n_overflow=ko)
    oracle = VectorOracle(T)
    state = oracle.init()
    key = jax.random.PRNGKey(seed)
    vecs = []
    for rnd in range(n_rounds):
        key, k1 = jax.random.split(key)
        slot = jax.random.randint(k1, (T,), 0, n_rec)
        batch = si.TxnBatch(
            tid=jnp.arange(T, dtype=jnp.int32),
            read_slots=slot[:, None].astype(jnp.int32),
            read_mask=jnp.ones((T, 1), bool),
            write_ref=jnp.zeros((T, 1), jnp.int32),
            write_mask=jnp.ones((T, 1), bool))

        def bump(rh, rd, vec, _r=rnd):
            return rd.astype(jnp.int32) + 1 + _r

        res = si.run_round(table, oracle, state, batch, bump)
        table, state = res.table, res.oracle_state
        table = mvcc.version_mover(table, reuse_only=True)
        vecs.append(np.asarray(state.vec).copy())
    return table, vecs, state


def _check_gc_safety(seed, n_rounds, max_txn_time):
    """P6 body: collect at the safe vector must not change any read at any
    snapshot ≥ safe (the snapshots transactions younger than E can hold)."""
    table, vecs, _ = _run_si_with_snapshots(seed, n_rounds)
    log = gc.init_log(n_snapshots=n_rounds, n_slots=len(vecs[0]))
    for t, v in enumerate(vecs):
        log = gc.take_snapshot(log, t, jnp.asarray(v, jnp.uint32))
    now = n_rounds - 1
    safe = gc.safe_vector(log, now, max_txn_time)
    swept = mvcc.compact_overflow(gc.collect(table, safe))
    safe_np = np.asarray(safe)
    admissible = [v for v in vecs if (v >= safe_np).all()]
    # snapshots younger than E are admissible by construction — non-vacuous
    assert len(admissible) >= min(len(vecs), max_txn_time)
    all_slots = jnp.arange(table.n_records, dtype=jnp.int32)
    for v in admissible:
        vec = jnp.asarray(v, jnp.uint32)
        a = mvcc.read_visible(table, all_slots, vec)
        b = mvcc.read_visible(swept, all_slots, vec)
        np.testing.assert_array_equal(np.asarray(a.found),
                                      np.asarray(b.found), err_msg=str(v))
        np.testing.assert_array_equal(np.asarray(a.hdr), np.asarray(b.hdr),
                                      err_msg=str(v))
        np.testing.assert_array_equal(np.asarray(a.data), np.asarray(b.data),
                                      err_msg=str(v))


def _check_gc_liveness(ko, lag, n_steps=None):
    """P7 body: single hot record, one install per step, mover in
    reclaimed-slot-only mode, a GC sweep per step at staleness ``lag``."""
    n_steps = n_steps or 4 * ko
    tbl = mvcc.init_table(1, 2, n_old=1, n_overflow=ko)
    s = jnp.array([0], jnp.int32)
    installed = 0
    v = 0
    for step in range(n_steps):
        v += 1
        out = mvcc.install(
            tbl, s, hdr.pack(jnp.uint32(1), jnp.uint32(v))[None],
            jnp.full((1, 2), v, jnp.int32), jnp.array([True]))
        installed += int(out.installed[0])
        if not bool(out.installed[0]):
            v -= 1                      # aborted: version v never existed
        tbl = mvcc.version_mover(out.table, reuse_only=True)
        safe = jnp.array([0, max(0, v - 1 - lag)], jnp.uint32)
        tbl = mvcc.compact_overflow(gc.collect(tbl, safe))
        assert 0 <= int(tbl.ovf_next[0]) < ko, "ring pointer escaped [0, KO)"
    assert installed >= 2 * ko, f"stall: only {installed}/{n_steps} installs"
    # slots were REUSED: some overflow version's cts exceeds the capacity,
    # impossible if each of the KO slots had been written at most once
    assert int(np.asarray(hdr.commit_ts(tbl.ovf_hdr[0])).max()) > ko


@given(seed=st.integers(0, 2**31 - 1), n_rounds=st.integers(2, 7),
       max_txn_time=st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_gc_collect_never_marks_newest_visible(seed, n_rounds, max_txn_time):
    _check_gc_safety(seed, n_rounds, max_txn_time)


@given(ko=st.integers(2, 6), lag=st.integers(0, 4))
@settings(max_examples=10, deadline=None)
def test_gc_mover_cycles_keep_overflow_ring_bounded(ko, lag):
    # a retention lag the ring cannot hold stalls by design (backpressure);
    # liveness is claimed for lag ≤ KO-2 — GC keeping up with the mover
    _check_gc_liveness(ko, min(lag, ko - 2))


# ---------------------------------------------------------------- P8 ------
_P8_ROUNDS = 4


def _journalled_mix(seed, failure):
    """One journalled single-shard TPC-C mix (checkpoint after every GC
    sweep), optionally killed and recovered at ``failure.kill_round``."""
    from repro.core.tsoracle import VectorOracle as _VO
    from repro.db import tpcc
    cfg = tpcc.TPCCConfig(n_warehouses=4, customers_per_district=8,
                          n_items=64, n_threads=8, orders_per_thread=16,
                          dist_degree=30.0)
    oracle = _VO(cfg.n_threads)
    lay, st0 = tpcc.init_tpcc(cfg, oracle, jax.random.PRNGKey(1))
    jnl = tpcc.make_journal(cfg, oracle, capacity_rounds=_P8_ROUNDS + 2)
    with tempfile.TemporaryDirectory() as d:
        st, ms = tpcc.run_mixed_rounds(
            cfg, lay, st0, oracle, jax.random.PRNGKey(seed), _P8_ROUNDS,
            journal=jnl, checkpoint_dir=d, failure=failure,
            gc_interval=2, max_txn_time=1)
    return st, ms


@given(seed=st.integers(0, 2**31 - 1), kill_round=st.integers(0, _P8_ROUNDS - 1),
       in_flight=st.booleans())
@settings(max_examples=5, deadline=None)
def test_kill_recover_is_bit_identical(seed, kill_round, in_flight):
    from repro.db import tpcc
    st_ref, ms_ref = _journalled_mix(seed, None)
    st_rec, ms_rec = _journalled_mix(
        seed, tpcc.FailureInjector(kill_round=kill_round,
                                   in_flight=in_flight))
    (rep,) = ms_rec.recovery
    assert rep.kill_round == kill_round
    assert rep.checkpoint_round < kill_round
    if in_flight:
        assert rep.undetermined > 0
    for leaf_a, leaf_b in zip(jax.tree.leaves(st_ref.nam.table),
                              jax.tree.leaves(st_rec.nam.table)):
        np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))
    np.testing.assert_array_equal(np.asarray(st_ref.nam.oracle_state.vec),
                                  np.asarray(st_rec.nam.oracle_state.vec))
    assert ms_ref.attempts == ms_rec.attempts
    assert ms_ref.commits == ms_rec.commits
    assert ms_ref.retries == ms_rec.retries
    assert ms_ref.delivered == ms_rec.delivered
    assert ms_ref.ops == ms_rec.ops


# ---------------------------------------------------------------- P9 ------
_P9_ROUNDS = 4


def _mesh_mix(seed, n_shards, growth):
    """One journalled mesh TPC-C mix, optionally grown mid-run.  6 threads:
    the partitioned vector divides over 2 shards but not over 4, so any
    expansion crosses a non-dividing (pad_vector) boundary."""
    from repro.core import store as store_mod
    from repro.core.tsoracle import PartitionedVectorOracle
    from repro.db import tpcc
    cfg = tpcc.TPCCConfig(n_warehouses=4, customers_per_district=8,
                          n_items=64, n_threads=6, orders_per_thread=16,
                          dist_degree=30.0)
    mesh = jax.make_mesh((n_shards,), ("mem",))
    oracle = PartitionedVectorOracle(cfg.n_threads, n_parts=n_shards)
    lay, st0 = tpcc.init_tpcc(cfg, oracle, jax.random.PRNGKey(1))
    engine = tpcc.make_mixed_engine(cfg, lay, mesh, "mem", oracle,
                                    shard_vector=True, with_journal=True)
    st0 = tpcc.distribute_state(engine, st0)
    jnl = tpcc.make_journal(cfg, oracle, capacity_rounds=_P9_ROUNDS + 2,
                            n_replicas=n_shards)
    jnl = store_mod.shard_journal(mesh, "mem", jnl)
    with tempfile.TemporaryDirectory() as d:
        st, ms = tpcc.run_mixed_rounds(
            cfg, lay, st0, oracle, jax.random.PRNGKey(seed), _P9_ROUNDS,
            engine=engine, journal=jnl, checkpoint_dir=d, growth=growth,
            gc_interval=2, max_txn_time=1)
    return lay, oracle, st, ms


@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="P9 needs a ≥4-device mesh (CI mesh step)")
@given(seed=st.integers(0, 2**31 - 1),
       grow_round=st.integers(0, _P9_ROUNDS - 1))
@settings(max_examples=3, deadline=None)
def test_expansion_preserves_committed_state(seed, grow_round):
    from repro.db import tpcc
    lay, oracle, st_ref, ms_ref = _mesh_mix(seed, 4, None)
    _, _, st_exp, ms_exp = _mesh_mix(
        seed, 2, tpcc.MeshGrowth(grow_round=grow_round, new_shards=4))
    (rep,) = ms_exp.growth
    assert rep.grow_round == grow_round
    assert rep.checkpoint_round < grow_round
    R = lay.catalog.total_records
    n_slots = oracle.n_slots
    tbl_ref = jax.tree.map(lambda x: jnp.asarray(jax.device_get(x))[:R],
                           st_ref.nam.table)
    tbl_exp = jax.tree.map(lambda x: jnp.asarray(jax.device_get(x))[:R],
                           st_exp.nam.table)
    for leaf_a, leaf_b in zip(jax.tree.leaves(tbl_ref),
                              jax.tree.leaves(tbl_exp)):
        np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))
    vec_ref = jnp.asarray(jax.device_get(
        st_ref.nam.oracle_state.vec))[:n_slots]
    vec_exp = jnp.asarray(jax.device_get(
        st_exp.nam.oracle_state.vec))[:n_slots]
    np.testing.assert_array_equal(np.asarray(vec_ref), np.asarray(vec_exp))
    assert ms_ref.attempts == ms_exp.attempts
    assert ms_ref.commits == ms_exp.commits
    assert ms_ref.retries == ms_exp.retries
    assert ms_ref.delivered == ms_exp.delivered
    assert ms_ref.ops == ms_exp.ops
    # the visible read of EVERY record at the final (admissible) snapshot
    # is unchanged by the expansion — not just raw storage equality
    slots = jnp.arange(R, dtype=jnp.int32)
    va = mvcc.read_visible(tbl_ref, slots, vec_ref)
    vb = mvcc.read_visible(tbl_exp, slots, vec_exp)
    for leaf_a, leaf_b in zip(jax.tree.leaves(va), jax.tree.leaves(vb)):
        np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))


# ------------------------------------------------------- MoE invariants ---
@given(st.integers(0, 2**31 - 1), st.integers(1, 4),
       st.sampled_from([1.0, 2.0, 8.0]))
@settings(max_examples=10, deadline=None)
def test_moe_combine_weights_sum(seed, top_k, cf):
    """Dropless capacity ⇒ outputs are convex combinations: if every expert
    computes identity, the MoE output equals the input."""
    from repro.models import moe as moe_mod
    D, E, Tk = 8, 4, 16
    key = jax.random.PRNGKey(seed)
    p = moe_mod.init_moe(key, D, D, E, jnp.float32)
    eye = jnp.broadcast_to(jnp.eye(D)[None], (E, D, D))
    p = dict(p, w_in=eye, w_out=eye,
             w_gate=jnp.zeros_like(p["w_gate"]))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (Tk, D))

    def act(g):          # silu(0)=0 would zero the output; use identity mix
        return jnp.ones_like(g)

    y, stats = moe_mod.apply_moe(p, x, top_k=top_k, capacity_factor=cf,
                                 activation=act)
    if cf >= E / max(1, top_k):          # provably dropless
        assert float(stats.dropped_fraction) == 0.0
        np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                                   rtol=2e-4, atol=2e-4)
